"""Bitonic in-kernel merge helpers (``kernels/merge.py``) vs a lexsort
oracle: the block-local sort, the sorted-run merge, and the combined
``merge_block_topl`` fold must all be bit-identical to lexicographic
(score asc, gid asc) selection — pads, ties and non-pow2 widths
included. These are the primitives the three streaming kernels trust
for exactness, so the oracle here is deliberately independent (numpy
lexsort, no jax sorting)."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.kernels import merge

_IMAX = np.iinfo(np.int32).max


def _oracle_sort(s, g):
    """Ascending (score, gid) lexicographic sort along the last axis —
    numpy lexsort's last key is primary."""
    s, g = np.asarray(s), np.asarray(g)
    out_s, out_g = np.empty_like(s), np.empty_like(g)
    for idx in np.ndindex(s.shape[:-1]):
        order = np.lexsort((g[idx], s[idx]))
        out_s[idx], out_g[idx] = s[idx][order], g[idx][order]
    return out_s, out_g


def _case(rng, shape, *, tie_heavy, pad_frac=0.0):
    """(scores, gids) with distinct gids per row — the kernels' invariant
    (global ids are unique) — plus optional canonical pad pairs."""
    s = (rng.integers(0, 4, size=shape).astype(np.float32) if tie_heavy
         else rng.standard_normal(shape).astype(np.float32))
    w = shape[-1]
    g = np.empty(shape, np.int32)
    for idx in np.ndindex(shape[:-1]):
        g[idx] = np.sort(rng.choice(10 * w, size=w, replace=False))
        rng.shuffle(g[idx])
    if pad_frac:
        pad = rng.random(shape) < pad_frac
        s = np.where(pad, np.inf, s)
        g = np.where(pad, _IMAX, g).astype(np.int32)
    return s, g


@settings(max_examples=25, deadline=None)
@given(
    w=st.integers(1, 97),
    rows=st.integers(1, 5),
    tie_heavy=st.sampled_from([False, True]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitonic_sort_matches_lexsort(w, rows, tie_heavy, seed):
    """Property: any width (pow2 or not), batched rows, tie-heavy scores
    and pad pairs — the sorting network's output is bitwise the lexsort
    order."""
    rng = np.random.default_rng(seed)
    s, g = _case(rng, (rows, w), tie_heavy=tie_heavy, pad_frac=0.15)
    got_s, got_g = merge.bitonic_sort_pairs(s, g)
    want_s, want_g = _oracle_sort(s, g)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_g), want_g)


@settings(max_examples=25, deadline=None)
@given(
    heap_w=st.integers(1, 64),
    block_w=st.integers(1, 64),
    topl=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge_sorted_pairs_matches_lexsort_prefix(heap_w, block_w, topl,
                                                   seed):
    """Merging two ascending runs == the sorted prefix of their
    concatenation (runs drawn from disjoint gid ranges, as heap and block
    are in the kernels)."""
    rng = np.random.default_rng(seed)
    hs, hg = _case(rng, (3, heap_w), tie_heavy=True, pad_frac=0.2)
    bs, bg = _case(rng, (3, block_w), tie_heavy=True, pad_frac=0.2)
    bg = np.where(bg == _IMAX, _IMAX, bg + 10 * heap_w * 10).astype(np.int32)
    hs, hg = _oracle_sort(hs, hg)
    bs, bg = _oracle_sort(bs, bg)
    got_s, got_g = merge.merge_sorted_pairs(hs, hg, bs, bg, topl)
    want_s, want_g = _oracle_sort(np.concatenate([hs, bs], -1),
                                  np.concatenate([hg, bg], -1))
    keep = min(topl, heap_w + block_w)
    np.testing.assert_array_equal(np.asarray(got_s), want_s[:, :keep])
    np.testing.assert_array_equal(np.asarray(got_g), want_g[:, :keep])


@settings(max_examples=25, deadline=None)
@given(
    topl=st.integers(1, 48),
    block_w=st.integers(1, 80),
    tie_heavy=st.sampled_from([False, True]),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge_block_topl_is_exact_fold(topl, block_w, tie_heavy, seed):
    """The kernels' actual step: a sorted (rows, topl) heap folded with an
    UNSORTED candidate block == lexsort top-L of heap + block. This is the
    exactness claim of the whole bitonic upgrade."""
    rng = np.random.default_rng(seed)
    hs, hg = _case(rng, (4, topl), tie_heavy=tie_heavy, pad_frac=0.3)
    hs, hg = _oracle_sort(hs, hg)
    bs, bg = _case(rng, (4, block_w), tie_heavy=tie_heavy, pad_frac=0.1)
    bg = np.where(bg == _IMAX, _IMAX, bg + 10 * topl * 10).astype(np.int32)
    got_s, got_g = merge.merge_block_topl(hs, hg, bs, bg, topl)
    want_s, want_g = _oracle_sort(np.concatenate([hs, bs], -1),
                                  np.concatenate([hg, bg], -1))
    np.testing.assert_array_equal(np.asarray(got_s), want_s[:, :topl])
    np.testing.assert_array_equal(np.asarray(got_g), want_g[:, :topl])


def test_all_pad_heap_and_degenerate_widths():
    """The heap's initial state (all canonical pads) and width-1 inputs
    are handled without special cases."""
    hs = np.full((2, 8), np.inf, np.float32)
    hg = np.full((2, 8), _IMAX, np.int32)
    bs = np.asarray([[3.0], [1.0]], np.float32)
    bg = np.asarray([[5], [9]], np.int32)
    got_s, got_g = merge.merge_block_topl(hs, hg, bs, bg, 8)
    np.testing.assert_array_equal(np.asarray(got_s)[:, 0], [3.0, 1.0])
    np.testing.assert_array_equal(np.asarray(got_g)[:, 0], [5, 9])
    np.testing.assert_array_equal(np.asarray(got_s)[:, 1:], hs[:, 1:])
    np.testing.assert_array_equal(np.asarray(got_g)[:, 1:], hg[:, 1:])

    s1, g1 = merge.bitonic_sort_pairs(bs, bg)
    np.testing.assert_array_equal(np.asarray(s1), bs)
    np.testing.assert_array_equal(np.asarray(g1), bg)
