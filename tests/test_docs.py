"""The documentation is executable and self-consistent.

  * every fenced ```python block in README.md and docs/*.md runs —
    blocks within one file share a namespace (so later blocks may build
    on earlier ones), and README's quickstart runs against a tiny
    in-memory dataset seeded by this harness (``train_vectors`` /
    ``base_vectors`` / ``queries``);
  * every intra-repo markdown link resolves to an existing file;
  * the factory-grammar table in docs/API.md is EXACTLY
    ``repro.index.factory.FACTORY_GRAMMAR`` — the doc cannot drift from
    the parser.

Non-runnable snippets (shell commands, pseudo-code, data-flow diagrams)
use plain or non-python fences and are skipped by construction.
"""
import pathlib
import re

import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted([ROOT / "README.md"] + list((ROOT / "docs").glob("*.md")),
                   key=lambda p: p.name)


def _python_blocks(text: str) -> list[str]:
    """Fenced ```python blocks, in order, as source strings."""
    blocks, cur = [], None
    for line in text.splitlines():
        if cur is None:
            if line.strip() == "```python":
                cur = []
        elif line.strip() == "```":
            blocks.append("\n".join(cur) + "\n")
            cur = None
        else:
            cur.append(line)
    return blocks


def _readme_namespace() -> dict:
    """The tiny in-memory dataset README's quickstart runs against."""
    rng = np.random.default_rng(0)
    return {
        "train_vectors": rng.normal(size=(400, 96)).astype(np.float32),
        "base_vectors": rng.normal(size=(600, 96)).astype(np.float32),
        "queries": rng.normal(size=(8, 96)).astype(np.float32),
    }


@pytest.mark.parametrize("path", DOC_FILES, ids=[p.name for p in DOC_FILES])
def test_doc_python_blocks_run(path):
    blocks = _python_blocks(path.read_text())
    if not blocks:
        pytest.skip(f"{path.name}: no python blocks")
    ns: dict = {"__name__": f"docsnippet_{path.stem}"}
    if path.name == "README.md":
        # keep the README quickstart honest but fast: UNQ trains for its
        # documented epochs over a 400-vector toy set (~seconds)
        ns.update(_readme_namespace())
    for i, block in enumerate(blocks):
        code = compile(block, f"{path.name}[python block {i}]", "exec")
        try:
            exec(code, ns)  # noqa: S102 — executing our own docs is the test
        except Exception as e:  # noqa: BLE001 — surface WHICH block broke
            pytest.fail(
                f"{path.name} python block {i} raised "
                f"{type(e).__name__}: {e}\n--- block ---\n{block}")


_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def test_intra_repo_links_resolve():
    """No dead links: every non-URL markdown link target in README and
    docs/ must exist relative to the file that links it."""
    dead = []
    for path in DOC_FILES:
        for m in _LINK_RE.finditer(path.read_text()):
            target = m.group(1).split("#")[0].strip()
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            if not (path.parent / target).exists():
                dead.append(f"{path.name} -> {m.group(1)}")
    assert not dead, f"dead intra-repo links: {dead}"


def test_api_grammar_table_matches_factory():
    """docs/API.md's grammar table is byte-for-byte FACTORY_GRAMMAR: the
    same components with the same descriptions, in the same order."""
    from repro.index import FACTORY_GRAMMAR

    text = (ROOT / "docs" / "API.md").read_text()
    rows = re.findall(r"^\| `([^`]+)` \| ([^|]+?) \|$", text, re.M)
    assert [tuple(r) for r in rows] == list(FACTORY_GRAMMAR), (
        "the grammar table in docs/API.md drifted from "
        "repro.index.factory.FACTORY_GRAMMAR — regenerate the table "
        "(one `| `component` | description |` row per grammar entry)")


def test_every_grammar_component_is_parseable():
    """Each documented component actually parses: substituting small
    numbers for the {placeholders} yields a spec index_factory accepts."""
    from repro.index import FACTORY_GRAMMAR, index_factory

    fills = {"UNQ{M}x{K}": "UNQ4x16", "PQ{M}[x{K}]": "PQ4x16",
             "OPQ{M}[x{K}]": "OPQ4x16", "RVQ{M}[x{K}]": "RVQ2x16",
             "IVF{nlist}": "IVF8", "NProbe{p}": "NProbe2",
             "Residual": "Residual", "Rerank{L}": "Rerank10",
             "Scan(name)": "Scan(xla)"}
    assert set(fills) == {c for c, _ in FACTORY_GRAMMAR}
    for comp, _ in FACTORY_GRAMMAR:
        token = fills[comp]
        if token.startswith(("UNQ", "PQ", "OPQ", "RVQ")):
            spec = token                      # a quantizer stands alone
        elif token == "IVF8":
            spec = "IVF8,PQ4x16"
        else:
            spec = f"IVF8,{token},PQ4x16"     # modifiers need IVF+quant
        index_factory(spec, dim=32)
