"""Gradient compression: quantization error bounds + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.optim import compress


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5000,)) * 3.0, jnp.float32)
    y = compress.compress_roundtrip(x)
    err = np.abs(np.asarray(y - x))
    scale_bound = float(jnp.max(jnp.abs(x))) / 127.0
    assert err.max() <= scale_bound * 0.5 + 1e-6


def test_int8_handles_odd_shapes_and_zeros():
    for shape in [(1,), (3, 5), (2049,), (7, 11, 13)]:
        x = jnp.zeros(shape, jnp.float32)
        y = compress.compress_roundtrip(x)
        assert y.shape == shape
        np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-9)


def test_error_feedback_accumulates_residual():
    opt = compress.with_error_feedback(optim.sgd(), scheme="int8")
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    # tiny gradient that quantizes to ~0 against its own scale is still
    # eventually applied thanks to EF accumulation across steps
    g = {"w": jnp.asarray([1e-4, -1e-4, 1e-4, -1e-4], jnp.float32)}
    p = params
    for _ in range(50):
        p, state = opt.apply(p, g, state, 1.0)
    moved = np.abs(np.asarray(p["w"]))
    assert (moved > 1e-4).all()   # ~50 steps x 1e-4 each = 5e-3 expected


def test_ef_sgd_converges_on_quadratic():
    """min ||x - t||^2 with int8-EF gradients converges like plain SGD."""
    t = jnp.asarray([1.0, -2.0, 3.0, 0.5])
    opt = compress.with_error_feedback(optim.sgd(), "int8")
    params = {"x": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    for _ in range(100):
        g = {"x": 2 * (params["x"] - t)}
        params, state = opt.apply(params, g, state, 0.1)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(t),
                               atol=1e-2)


def test_compressed_psum_matches_mean_psum():
    """shard_map int8 all-reduce approximates the exact mean."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro.utils.compat import shard_map

mesh = jax.make_mesh((4,), ("d",))
x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.0

def f(xs):
    return compressed_psum(xs[0], "d")

y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d", None),
                      out_specs=P()))(x)
want = np.asarray(x).mean(0)
np.testing.assert_allclose(np.asarray(y), want, rtol=0.02, atol=0.02)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd=".")
    assert "OK" in r.stdout, r.stderr[-2000:]
