"""Residual IVF encoding (IVFADC): the acceptance properties.

  * the trainer pipeline is ordered — coarse k-means first, the wrapped
    quantizer second, trained on ``x - centroid(x)`` (measured: residual
    codebooks live at residual scale, far below data scale);
  * stage-1 d2 scores under the exact correction ARE the distances to
    the implied ``centroid + decode(code)`` reconstruction (semantic
    check, fp tolerance), and every streaming path agrees bit-for-bit
    with the materialized residual oracle (ref scan + the composed bias
    streams);
  * on INTEGER data (exact float arithmetic, ubiquitous ties) full
    search is bit-identical to a brute-force ``centroid + decode``
    oracle on xla, pallas-interpret AND onehot — ties included;
  * all residual rerankers (extended-table fused/chunked, dedup+centroid,
    materialized vmap) produce bit-identical d1;
  * plain (non-residual) IVF paths are untouched: the residual flag off
    reproduces the pre-residual behavior (covered by tests/test_ivf.py's
    full-probe == flat properties, which must keep passing);
  * by-cell host sharding, filtered search, incremental adds, save/load
    and ``use_d2=False`` all compose with residual encoding.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines as bl
from repro.index import IVFIndex, Index, ShardedIndex, index_factory
from repro.index.rerank import (DedupRerank, ResidualRerank, TableRerank,
                                VmapRerank, reranker_for)
from repro.kernels import ref

_IMAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# training pipeline
# ---------------------------------------------------------------------------

def test_train_pipeline_order_and_residual_feed(tiny_dataset):
    """The IVF trainer pipeline runs coarse k-means BEFORE the wrapped
    quantizer, and in residual mode the quantizer stage sees residuals:
    its codebooks land at residual scale (far below data scale), while a
    plain IVF quantizer stays at data scale."""
    ivf = index_factory("IVF16,Residual,PQ4x32,Rerank50",
                        dim=tiny_dataset.dim)
    stages = [s.name for s in ivf._train_stages()]
    assert stages == ["coarse", "pq"]
    ivf.train(tiny_dataset.train, iters=4)
    plain = index_factory("IVF16,PQ4x32,Rerank50", dim=tiny_dataset.dim)
    plain.train(tiny_dataset.train, iters=4)

    def codebook_scale(index):
        table = np.asarray(index.inner._decode_table())
        return float(np.linalg.norm(table.sum(axis=0), axis=-1).mean())

    data_scale = float(np.linalg.norm(tiny_dataset.train, axis=1).mean())
    assert codebook_scale(ivf) < 0.5 * data_scale
    assert codebook_scale(plain) > 0.5 * data_scale
    # the residual flag reaches metadata and repr
    assert ivf._metadata()["residual"] is True
    assert "residual=True" in repr(ivf)


def test_residual_requires_ivf_and_parses():
    with pytest.raises(ValueError, match="Residual"):
        index_factory("Residual,PQ4x32", dim=32)
    index = index_factory("IVF8,Residual,PQ4x32", dim=32)
    assert isinstance(index, IVFIndex) and index.residual


# ---------------------------------------------------------------------------
# stage-1 correction: semantic + bitwise-vs-oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["IVF8,Residual,PQ4x32,Rerank50",
                                  "IVF8,Residual,RVQ2x32,Rerank50"])
def test_stage1_scores_are_recon_distances(trained_index_factory,
                                           tiny_dataset, spec):
    """Semantic acceptance: with the exact correction, the d2 score of
    every surfaced candidate equals ||q - (centroid + decode(code))||^2
    (RVQ scores carry their usual -||q||^2 per-query offset) up to fp
    rounding — the correction is a distance, not a heuristic."""
    ivf = trained_index_factory(spec, iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:16])
    d2, ids = ivf.search(queries, 10, nprobe=ivf.nlist, use_rerank=False)
    d2, ids = np.asarray(d2), np.asarray(ids)
    rows = np.asarray(jnp.take(ivf._pos_dev, jnp.asarray(ids)))
    recon = np.asarray(
        ivf.reconstruct_rows(rows.ravel())).reshape(*ids.shape, ivf.dim)
    q_np = np.asarray(queries, np.float64)
    true = ((q_np[:, None, :] - recon.astype(np.float64)) ** 2).sum(-1)
    if spec.startswith("IVF8,Residual,RVQ"):
        true = true - (q_np ** 2).sum(-1)[:, None]
    scale = np.maximum(np.abs(true), 1.0)
    np.testing.assert_allclose(d2, true, atol=5e-3 * scale.max())


@pytest.mark.parametrize("spec", ["IVF8,Residual,PQ4x32,Rerank50",
                                  "IVF8,Residual,RVQ2x32,Rerank50"])
def test_stage1_paths_bitwise_vs_residual_oracle(trained_index_factory,
                                                 tiny_dataset, spec):
    """Every streaming stage-1 path (chunked xla, fused pallas-interpret)
    is bit-identical to the materialized residual oracle: the ref gather
    scan over the same plan with the bias streams composed exactly as
    ``_plan_rowbias`` composes them (per-row cross term first, then the
    per-(query, cell) coarse term)."""
    from repro.kernels import ops
    ivf = trained_index_factory(spec, iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:12])
    cd = ivf._coarse_dists(queries)
    for nprobe in (2, ivf.nlist):
        probe = ivf.probe_cells(queries, nprobe)
        rows_np, gids_np, cells_np = ivf._probe_plan(probe)
        rows, gids = jnp.asarray(rows_np), jnp.asarray(gids_np)
        rowbias = ivf._plan_rowbias(rows, gids, ivf.bias, None,
                                    queries.shape[0],
                                    slot_cells=cells_np, cell_bias=cd)
        luts = ivf._build_luts(queries)
        want = ref.adc_gather_topl_ref(ivf.codes, rows, gids, luts,
                                       rowbias, 50)
        for impl in ("xla", "pallas"):
            got = ops.adc_gather_topl(ivf.codes, rows, gids, luts,
                                      topl=50, rowbias=rowbias, impl=impl)
            np.testing.assert_array_equal(
                np.asarray(got[0]), np.asarray(want[0]),
                err_msg=f"{impl} nprobe={nprobe} scores")
            np.testing.assert_array_equal(
                np.asarray(got[1]), np.asarray(want[1]),
                err_msg=f"{impl} nprobe={nprobe} ids")


# ---------------------------------------------------------------------------
# integer-exact end-to-end oracle: all three backends, ties included
# ---------------------------------------------------------------------------

def _integer_residual_ivf(rng, n, dim=16, m=4, k=8, nlist=6, rerank=30):
    """A hand-built residual PQ/IVF index over INTEGER codebooks,
    centroids and data: every score and distance is exactly
    representable, collisions are ubiquitous, so search parity against
    the brute-force oracle tests tie resolution end to end."""
    books = jnp.asarray(rng.integers(-2, 3, (m, k, dim // m)), jnp.float32)
    ivf = index_factory(f"IVF{nlist},Residual,PQ{m}x{k},Rerank{rerank}",
                        dim=dim)
    ivf.inner.model = bl.PQModel(books)
    ivf.coarse = jnp.asarray(rng.integers(-2, 3, (nlist, dim)), jnp.float32)
    data = rng.integers(-2, 3, (n, dim)).astype(np.float32)
    ivf.add(data)
    return ivf, data


def test_integer_residual_bit_exact_on_every_backend():
    """Acceptance: residual IVF search — stage 1 AND rerank, partial and
    full probe — is bit-identical to a brute-force oracle that
    materializes ``centroid + decode(code)`` and sorts by
    (distance, global id), on xla, pallas-interpret AND onehot. Integer
    data makes float arithmetic exact, so association differences cannot
    hide and ties are everywhere."""
    rng = np.random.default_rng(7)
    ivf, data = _integer_residual_ivf(rng, n=400)
    queries = jnp.asarray(rng.integers(-2, 3, (12, ivf.dim)), jnp.float32)
    q_np = np.asarray(queries)

    rows_all = np.asarray(jnp.take(ivf._pos_dev, jnp.arange(ivf.ntotal)))
    recon = np.asarray(ivf.reconstruct_rows(rows_all))      # add order
    dist = ((q_np[:, None, :] - recon[None, :, :]) ** 2).sum(-1)
    cells_add = ivf._cells_np[rows_all]
    assert (dist == dist.astype(np.float32)).all()          # exact in f32

    for nprobe in (2, ivf.nlist):
        probe = ivf.probe_cells(queries, nprobe)
        for k in (10, 25):      # <= the rerank budget: pool width == k
            want_d, want_i = [], []
            for qi in range(q_np.shape[0]):
                elig = np.isin(cells_add, probe[qi])
                order = sorted(np.flatnonzero(elig),
                               key=lambda g: (dist[qi, g], g))[:k]
                dd = [dist[qi, g] for g in order]
                ii = list(order)
                while len(dd) < min(k, ivf.ntotal):
                    dd.append(np.inf)
                    ii.append(-1)
                want_d.append(dd)
                want_i.append(ii)
            want_d = np.asarray(want_d, np.float32)
            want_i = np.asarray(want_i, np.int32)
            for backend in ("xla", "pallas", "onehot"):
                ivf.backend = backend
                got_d, got_i = ivf.search(queries, k, nprobe=nprobe)
                np.testing.assert_array_equal(
                    np.asarray(got_i), want_i,
                    err_msg=f"{backend} nprobe={nprobe} k={k} idx")
                np.testing.assert_array_equal(
                    np.asarray(got_d), want_d,
                    err_msg=f"{backend} nprobe={nprobe} k={k} dist")
                # use_rerank=False: d2 == d1 here (the correction is
                # exact and arithmetic is integer), same ranking
                got_d2, got_i2 = ivf.search(queries, k, nprobe=nprobe,
                                            use_rerank=False)
                np.testing.assert_array_equal(np.asarray(got_i2), want_i,
                                              err_msg=f"{backend} no-rr")
                np.testing.assert_array_equal(np.asarray(got_d2), want_d,
                                              err_msg=f"{backend} no-rr d")


def test_integer_residual_exhaustive_matches_oracle():
    """use_d2=False over a residual index ranks the whole database by
    exact ``centroid + decode`` distances (integer-exact, so bitwise)."""
    rng = np.random.default_rng(8)
    ivf, _ = _integer_residual_ivf(rng, n=300)
    queries = jnp.asarray(rng.integers(-2, 3, (8, ivf.dim)), jnp.float32)
    rows_all = np.asarray(jnp.take(ivf._pos_dev, jnp.arange(ivf.ntotal)))
    recon = np.asarray(ivf.reconstruct_rows(rows_all))
    dist = ((np.asarray(queries)[:, None, :] - recon[None, :, :]) ** 2
            ).sum(-1)
    neg, idx = jax.lax.top_k(-jnp.asarray(dist, jnp.float32), 15)
    got_d, got_i = ivf.search(queries, 15, use_d2=False)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(-neg))


# ---------------------------------------------------------------------------
# stage 2: all residual rerankers agree bitwise
# ---------------------------------------------------------------------------

def test_residual_rerankers_bitwise_identical(trained_index_factory,
                                              tiny_dataset):
    """The three residual rerank routes — extended-table (fused pallas /
    chunked xla), dedup+centroid, materialized vmap — produce
    bit-identical d1 over the same candidate rows."""
    ivf = trained_index_factory("IVF8,Residual,PQ4x32,Rerank50", iters=4)
    rng = np.random.default_rng(3)
    queries = jnp.asarray(tiny_dataset.queries[:9])
    cand = jnp.asarray(rng.integers(0, ivf.ntotal, (9, 40)), jnp.int32)
    routes = {
        "table-xla": ResidualRerank(TableRerank("xla")),
        "table-pallas": ResidualRerank(TableRerank("pallas")),
        "dedup": ResidualRerank(DedupRerank(add_centroid=True)),
        "vmap": ResidualRerank(VmapRerank()),
    }
    outs = {name: np.asarray(rr.distances(ivf, queries, cand))
            for name, rr in routes.items()}
    for name, got in outs.items():
        np.testing.assert_array_equal(got, outs["vmap"], err_msg=name)


def test_reranker_resolution_wraps_residual(trained_index_factory):
    res = trained_index_factory("IVF8,Residual,PQ4x32,Rerank50", iters=4)
    plain = trained_index_factory("IVF8,PQ4x32,Rerank50", iters=4)
    assert isinstance(reranker_for(res), ResidualRerank)
    assert not isinstance(reranker_for(plain), ResidualRerank)
    res.backend = "onehot"
    rr = reranker_for(res)
    assert isinstance(rr, ResidualRerank)
    assert isinstance(rr.inner, VmapRerank)
    # wrapping a DedupRerank ALWAYS forces the centroid add — the
    # natural composition cannot silently rank bare residual decodes
    assert ResidualRerank(DedupRerank()).inner.add_centroid


def test_nlist_above_book_size_routes_through_dedup():
    """When nlist > K the extended decode table would pad every face to
    nlist; those residual indexes rerank through the dedup route instead
    (bit-identical d1 — checked against the vmap oracle here)."""
    rng = np.random.default_rng(11)
    ivf, _ = _integer_residual_ivf(rng, n=300, nlist=20, k=8)
    assert ivf.nlist > ivf.inner._decode_table().shape[1]
    rr = reranker_for(ivf)
    assert isinstance(rr, ResidualRerank)
    assert isinstance(rr.inner, DedupRerank) and rr.inner.add_centroid
    queries = jnp.asarray(rng.integers(-2, 3, (6, ivf.dim)), jnp.float32)
    cand = jnp.asarray(rng.integers(0, ivf.ntotal, (6, 25)), jnp.int32)
    got = np.asarray(rr.distances(ivf, queries, cand))
    want = np.asarray(
        ResidualRerank(VmapRerank()).distances(ivf, queries, cand))
    np.testing.assert_array_equal(got, want)


def test_residual_unq_reranks_exact_reconstruction(tiny_dataset):
    """Residual + decoder quantizer (UNQ): stage 1 is a proxy (documented)
    but stage 2 reranks against the exact centroid + decode
    reconstruction, and xla/pallas agree bitwise."""
    ivf = index_factory("IVF4,Residual,UNQ4x16,Rerank50",
                        dim=tiny_dataset.dim)
    ivf.train(tiny_dataset.train[:600], epochs=2, log_every=1000)
    ivf.add(tiny_dataset.base[:800])
    queries = jnp.asarray(tiny_dataset.queries[:8])
    d, i = ivf.search(queries, 10, nprobe=4)
    d, i = np.asarray(d), np.asarray(i)
    rows = np.asarray(jnp.take(ivf._pos_dev,
                               jnp.asarray(np.where(i < 0, 0, i))))
    recon = ivf.reconstruct_rows(rows.ravel())
    true = np.asarray(jax.jit(
        lambda q, r: jnp.sum(jnp.square(r - q[:, None, :]), -1))(
        queries, recon.reshape(*i.shape, ivf.dim)))
    finite = np.isfinite(d)
    assert finite.any()
    np.testing.assert_allclose(d[finite], true[finite], rtol=1e-4,
                               atol=1e-4)
    ivf.backend = "pallas"
    d2, i2 = ivf.search(queries, 10, nprobe=4)
    np.testing.assert_array_equal(np.asarray(i2), i)
    np.testing.assert_array_equal(np.asarray(d2), d)


# ---------------------------------------------------------------------------
# composition: sharding, filtering, incremental adds, persistence
# ---------------------------------------------------------------------------

def test_sharded_residual_matches_unsharded(trained_index_factory):
    """By-cell host sharding reproduces the unsharded residual result
    bit-for-bit for every nprobe (the per-(query, cell) correction rides
    each shard's slot-bias stream)."""
    ivf = trained_index_factory("IVF8,Residual,RVQ2x32,Rerank50", iters=4)
    rng = np.random.default_rng(5)
    queries = jnp.asarray(rng.normal(size=(10, ivf.dim)), jnp.float32)
    for num_shards in (1, 3):
        sharded = ShardedIndex(ivf, num_shards=num_shards)
        assert sharded.resolved_placement == "host"
        for nprobe in (2, 8):
            dw, iw = ivf.search(queries, 12, nprobe=nprobe)
            dg, ig = sharded.search(queries, 12, nprobe=nprobe)
            np.testing.assert_array_equal(
                np.asarray(ig), np.asarray(iw),
                err_msg=f"shards={num_shards} nprobe={nprobe}")
            np.testing.assert_array_equal(
                np.asarray(dg), np.asarray(dw),
                err_msg=f"shards={num_shards} nprobe={nprobe}")


def test_residual_filter_mask_composes(trained_index_factory):
    """filter_mask + residual: masked ids never surface on any backend
    and a fully-masked query reports all (+inf, -1)."""
    ivf = trained_index_factory("IVF8,Residual,PQ4x32,Rerank50", iters=4)
    rng = np.random.default_rng(6)
    q = 8
    queries = jnp.asarray(rng.normal(size=(q, ivf.dim)), jnp.float32)
    mask = rng.integers(0, 2, ivf.ntotal).astype(bool)
    for backend in ("xla", "pallas", "onehot"):
        ivf.backend = backend
        d, i = ivf.search(queries, 12, nprobe=8, filter_mask=mask)
        for x in np.asarray(i).ravel():
            assert x == -1 or mask[x], backend
    maskq = rng.integers(0, 2, (q, ivf.ntotal)).astype(bool)
    maskq[2, :] = False
    d, i = ivf.search(queries, 12, nprobe=8, filter_mask=maskq)
    d, i = np.asarray(d), np.asarray(i)
    assert (i[2] == -1).all() and np.isinf(d[2]).all()
    for qi in range(q):
        for x in i[qi]:
            assert x == -1 or maskq[qi, x]


def test_residual_incremental_adds_match_bulk(trained_index_factory):
    """Chunked adds regroup into the same residual index state as one
    bulk add: identical cross-term biases, cells and search results."""
    master = trained_index_factory("IVF8,Residual,PQ4x32,Rerank50", iters=4)
    rng = np.random.default_rng(2)
    data = rng.normal(size=(300, master.dim)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(6, master.dim)), jnp.float32)

    def fresh():
        index = IVFIndex(master.dim, inner=master.inner, nlist=8,
                         nprobe=4, rerank=50, residual=True)
        index.coarse = master.coarse
        return index

    one = fresh().add(data)
    chunked = fresh()
    for lo, hi in ((0, 100), (100, 103), (103, 300)):
        chunked.add(data[lo:hi])
    np.testing.assert_array_equal(chunked._ids_np, one._ids_np)
    np.testing.assert_array_equal(chunked._cells_np, one._cells_np)
    np.testing.assert_array_equal(np.asarray(chunked.bias),
                                  np.asarray(one.bias))
    for nprobe in (2, 8):
        dw, iw = one.search(queries, 10, nprobe=nprobe)
        dg, ig = chunked.search(queries, 10, nprobe=nprobe)
        np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw))
        np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw))


def test_residual_save_load_roundtrip(trained_index_factory, tiny_dataset,
                                      tmp_path):
    ivf = trained_index_factory("IVF8,Residual,PQ4x32,Rerank50", iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:8])
    want_d, want_i = ivf.search(queries, 12, nprobe=4)
    ivf.save(tmp_path / "ck")
    loaded = Index.load(tmp_path / "ck")
    assert isinstance(loaded, IVFIndex) and loaded.residual
    got_d, got_i = loaded.search(queries, 12, nprobe=4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))


# ---------------------------------------------------------------------------
# the point of it all: residual codes reconstruct better
# ---------------------------------------------------------------------------

def test_residual_reconstruction_beats_plain(trained_index_factory,
                                             tiny_dataset):
    """At a matched code budget, residual encoding reconstructs the base
    vectors strictly better than plain encoding (that is the entire
    IVFADC argument: codebook capacity against the low-variance residual
    distribution; the margin here is modest because the synthetic set's
    64 clusters overflow the 16 coarse cells — the benchmark's recall
    study tracks the end-to-end effect)."""
    res = trained_index_factory("IVF16,Residual,PQ4x32,Rerank50", iters=4)
    plain = trained_index_factory("IVF16,PQ4x32,Rerank50", iters=4)
    base = np.asarray(tiny_dataset.base)

    def mse(index):
        rows = np.asarray(jnp.take(index._pos_dev,
                                   jnp.arange(index.ntotal)))
        recon = np.asarray(index.reconstruct_rows(rows))
        return float(((recon - base) ** 2).sum(-1).mean())

    assert mse(res) < 0.95 * mse(plain), (mse(res), mse(plain))
