"""MoE-style IVF probe dispatch: the acceptance properties.

  * the device router's dense per-cell batches + the cell-batched scan
    (``ops.adc_dispatch_topl``: fused pallas kernel in interpret mode /
    chunked xla) agree bit-for-bit with the materialized
    ``adc_dispatch_topl_ref`` oracle on random cell-grouped buffers —
    ties, biases, (Q, N) keep streams and empty cells included;
  * the scatter-merged per-query pools are bit-identical to the padded
    gathered plan over the same probe, and ``IVFIndex.search`` with
    ``use_dispatch=True`` reproduces the padded path (and flat search at
    ``nprobe == nlist``) exactly — filters, residual correction, rerank;
  * degenerate inputs agree across faces: empty cells, nprobe > nlist,
    all-masked queries, pools smaller than k;
  * the capacity factor is respected under skew (per-cell batches never
    exceed the budget) and overflow falls back LOUDLY to the padded
    plan — never a silent candidate drop;
  * the memoized flat-lexsort ``_probe_plan`` reproduces the original
    per-row-argsort construction and caches repeated probes.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.index import index_factory
from repro.index.candidates import supports_dispatch
from repro.index.dispatch import (build_dispatch, build_shard_dispatch,
                                  combine_pools, route_stats)
from repro.kernels import ops, ref

_IMAX = np.iinfo(np.int32).max


def _cell_grouped_case(rng, nlist, q, p, m=4, k=16, max_cell=40):
    """Random cell-grouped buffer (empty cells included, gids ascending
    within cells — the CSR invariant), tie-heavy integer LUTs, and a
    per-query probe of distinct cells."""
    sizes = rng.integers(0, max_cell, size=nlist)
    if nlist > 2:
        sizes[rng.integers(0, nlist)] = 0          # force an empty cell
    offsets = np.zeros(nlist + 1, np.int64)
    offsets[1:] = np.cumsum(sizes)
    n = int(offsets[-1])
    codes = rng.integers(0, k, size=(max(n, 1), m)).astype(np.uint8)[:n]
    gids = np.sort(rng.choice(4 * max(n, 1), size=max(n, 1),
                              replace=False))[:n].astype(np.int32)
    luts = rng.integers(0, 3, size=(q, m, k)).astype(np.float32)
    p = min(p, nlist)
    probe = np.stack([rng.choice(nlist, size=p, replace=False)
                      for _ in range(q)]).astype(np.int32)
    return offsets, codes, gids, luts, probe


def _padded_pool(codes, gids, offsets, probe, luts, rowbias_n, qkeep, topl):
    """The padded gathered plan over the same probe — the control the
    dispatch partial pools must reproduce after the scatter-merge."""
    q, _ = probe.shape
    per = []
    w = 1
    for qi in range(q):
        rows = np.concatenate(
            [np.arange(offsets[c], offsets[c + 1]) for c in probe[qi]]
        ).astype(np.int64)
        g = gids[rows]
        o = np.argsort(g, kind="stable")
        per.append((rows[o], g[o]))
        w = max(w, rows.size)
    rows_a = np.zeros((q, w), np.int32)
    gids_a = np.full((q, w), _IMAX, np.int32)
    for qi, (r, g) in enumerate(per):
        rows_a[qi, :r.size] = r
        gids_a[qi, :g.size] = g
    rb = None
    if rowbias_n is not None or qkeep is not None:
        base = rowbias_n if rowbias_n is not None \
            else np.zeros(codes.shape[0], np.float32)
        rb = jnp.asarray(base)[jnp.asarray(rows_a)]
        if qkeep is not None:
            keep = jnp.take_along_axis(jnp.asarray(qkeep),
                                       jnp.asarray(rows_a), axis=1)
            rb = jnp.where(keep > 0.5, rb, jnp.inf)
    return ops.adc_gather_topl(
        jnp.asarray(codes), jnp.asarray(rows_a), jnp.asarray(gids_a),
        jnp.asarray(luts), topl=min(topl, w), rowbias=rb, impl="xla")


@settings(max_examples=10, deadline=None)
@given(
    nlist=st.integers(1, 12),
    q=st.integers(1, 7),
    p=st.integers(1, 6),
    topl=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_topl_matches_ref_oracle_and_padded(nlist, q, p, topl,
                                                     seed):
    """Property: per-cell partial pools from the chunked xla path and the
    fused pallas kernel (interpret mode) are bit-identical to the
    materialized ``adc_dispatch_topl_ref`` oracle, and the scatter-merged
    per-query pools are bit-identical to the padded gathered plan —
    random biases, (Q, N) keep streams and tie-heavy scores included."""
    rng = np.random.default_rng(seed)
    offsets, codes, gids, luts, probe = _cell_grouped_case(rng, nlist, q, p)
    n = codes.shape[0]
    if n == 0:
        return
    chunk = 8
    routing, _ = build_dispatch(probe, offsets, chunk=chunk)
    assert routing is not None and int(routing.overflow) == 0
    plan = routing.plan

    rowbias = rng.integers(0, 2, size=(n,)).astype(np.float32) \
        if rng.integers(0, 2) else None
    qkeep = (rng.random((q, n)) > 0.3).astype(np.float32) \
        if rng.integers(0, 2) else None
    cap = plan.qidx.shape[1]
    cellterm = np.where(np.asarray(plan.qidx) >= 0,
                        rng.integers(0, 2, size=(routing.cell_of.shape[0],
                                                 cap)),
                        0.0).astype(np.float32)

    rb_ref = jnp.zeros(n, jnp.float32) if rowbias is None \
        else jnp.asarray(rowbias)
    want_s, want_g = ref.adc_dispatch_topl_ref(
        jnp.asarray(codes), jnp.asarray(gids), rb_ref,
        jnp.asarray(luts), jnp.asarray(cellterm), plan.qidx,
        routing.cell_lo, routing.cell_hi, topl,
        qkeep=None if qkeep is None else jnp.asarray(qkeep))
    routed = np.asarray(jnp.any(plan.qidx >= 0, axis=1))[:, None, None]
    want_s = np.where(routed, np.asarray(want_s), np.inf)
    want_g = np.where(routed, np.asarray(want_g), _IMAX)

    for impl in ("xla", "pallas"):
        got_s, got_g = ops.adc_dispatch_topl(
            jnp.asarray(codes), jnp.asarray(gids),
            None if rowbias is None else jnp.asarray(rowbias),
            jnp.asarray(luts), jnp.asarray(cellterm), plan, topl=topl,
            qkeep=None if qkeep is None else jnp.asarray(qkeep),
            impl=impl, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(got_s), want_s,
                                      err_msg=f"{impl} scores")
        np.testing.assert_array_equal(np.asarray(got_g), want_g,
                                      err_msg=f"{impl} gids")

    # scatter-merge vs the padded gathered plan (cellterm excluded: the
    # padded control composes it per slot-cell, exercised end-to-end by
    # the residual index tests below — here zero it for a direct match)
    zero_ct = jnp.zeros_like(jnp.asarray(cellterm))
    part_s, part_g = ops.adc_dispatch_topl(
        jnp.asarray(codes), jnp.asarray(gids),
        None if rowbias is None else jnp.asarray(rowbias),
        jnp.asarray(luts), zero_ct, plan, topl=topl,
        qkeep=None if qkeep is None else jnp.asarray(qkeep), impl="xla",
        chunk=chunk)
    got = combine_pools(part_s, part_g, routing.comb_e, routing.comb_slot,
                        topl=topl)
    want = _padded_pool(codes, gids, offsets, probe, luts, rowbias, qkeep,
                        topl)
    width = min(got[0].shape[1], want[0].shape[1])
    np.testing.assert_array_equal(np.asarray(got[0])[:, :width],
                                  np.asarray(want[0])[:, :width])
    np.testing.assert_array_equal(np.asarray(got[1])[:, :width],
                                  np.asarray(want[1])[:, :width])
    # any extra columns on either side are canonical (+inf, _IMAX) pads
    for arr, pad in ((got[0], np.inf), (want[0], np.inf),
                     (got[1], _IMAX), (want[1], _IMAX)):
        tail = np.asarray(arr)[:, width:]
        assert (tail == pad).all()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("spec", ["IVF8,PQ4x16", "IVF8,Residual,PQ4x16",
                                  "IVF8,RVQ2x16"])
def test_search_dispatch_equals_padded(backend, spec):
    """``use_dispatch=True`` reproduces the padded path bit-for-bit on
    every dispatch-capable backend — nprobe sweeps (> nlist included),
    per-point and per-query filters, all-masked queries, rerank on/off,
    residual correction and RVQ bias streams."""
    rng = np.random.default_rng(3)
    d, n, q = 16, 500, 8
    xs = rng.integers(0, 3, size=(n, d)).astype(np.float32)
    queries = rng.integers(0, 3, size=(q, d)).astype(np.float32)
    ivf = index_factory(spec, d, backend=backend)
    ivf.rerank = 20
    ivf.train(xs, iters=4)
    ivf.add(xs)
    masks = [None, rng.random(n) > 0.4, rng.random((q, n)) > 0.4]
    masks[2][0, :] = False                         # an all-masked query
    for nprobe in (1, 3, 8, 99):
        for mask in masks:
            for use_rerank in (False, True):
                d_pad, i_pad = ivf.search(
                    queries, 10, nprobe=nprobe, filter_mask=mask,
                    use_rerank=use_rerank, use_dispatch=False)
                d_dis, i_dis = ivf.search(
                    queries, 10, nprobe=nprobe, filter_mask=mask,
                    use_rerank=use_rerank, use_dispatch=True)
                tag = f"nprobe={nprobe} rerank={use_rerank}"
                np.testing.assert_array_equal(np.asarray(d_pad),
                                              np.asarray(d_dis),
                                              err_msg=tag)
                np.testing.assert_array_equal(np.asarray(i_pad),
                                              np.asarray(i_dis),
                                              err_msg=tag)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_search_dispatch_full_probe_equals_flat(ivf_flat_pair, backend,
                                                tiny_dataset):
    """At ``nprobe == nlist`` the dispatch face lands exactly on flat
    search — the cells partition the database and every face shares one
    tie-break (rerank on and off)."""
    ivf, flat = ivf_flat_pair("PQ4x32", 8, rerank=50, iters=4)
    flat.backend = backend
    ivf.backend = backend
    queries = tiny_dataset.queries[:12]
    for kw in (dict(), dict(use_rerank=False)):
        d_f, i_f = flat.search(queries, 10, **kw)
        d_d, i_d = ivf.search(queries, 10, nprobe=ivf.nlist,
                              use_dispatch=True, **kw)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_d))
        np.testing.assert_array_equal(np.asarray(d_f), np.asarray(d_d))


def test_dispatch_capability_gating():
    """onehot has no dispatch face: the default quietly stays padded, an
    explicit ``use_dispatch=True`` is a loud error, and the capability
    helper reports all three backends correctly."""
    assert supports_dispatch("xla") and supports_dispatch("pallas")
    assert not supports_dispatch("onehot")
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((200, 8)).astype(np.float32)
    ivf = index_factory("IVF4,PQ2x16", 8, backend="onehot")
    ivf.train(xs, iters=3)
    ivf.add(xs)
    d, i = ivf.search(xs[:5], 4)                   # default: padded, works
    assert d.shape == (5, 4)
    with pytest.raises(ValueError, match="dispatch_topl"):
        ivf.search(xs[:5], 4, use_dispatch=True)


def test_dispatch_degenerate_tiny_index():
    """Degenerate shapes agree across faces: nlist far above ntotal (most
    cells empty), k above the pool width, single query, nprobe > nlist."""
    rng = np.random.default_rng(1)
    xs = rng.standard_normal((9, 8)).astype(np.float32)
    ivf = index_factory("IVF16,PQ2x16", 8, backend="xla")
    ivf.train(rng.standard_normal((64, 8)).astype(np.float32), iters=3)
    ivf.add(xs)
    for q, nprobe, k in ((1, 1, 5), (3, 2, 20), (2, 40, 9)):
        queries = rng.standard_normal((q, 8)).astype(np.float32)
        d_pad, i_pad = ivf.search(queries, k, nprobe=nprobe,
                                  use_dispatch=False)
        d_dis, i_dis = ivf.search(queries, k, nprobe=nprobe,
                                  use_dispatch=True)
        np.testing.assert_array_equal(np.asarray(d_pad), np.asarray(d_dis))
        np.testing.assert_array_equal(np.asarray(i_pad), np.asarray(i_dis))
        assert ((np.asarray(i_dis) >= -1)
                & (np.asarray(i_dis) < ivf.ntotal)).all()


def test_capacity_factor_respected_under_skew():
    """Load balance: with a capacity factor set and a heavily skewed
    probe (every query hammers the same cells), routed per-cell batches
    never exceed the ceil(factor * Q * P / E) budget."""
    rng = np.random.default_rng(2)
    nlist, q = 16, 32
    sizes = rng.integers(1, 20, size=nlist)
    offsets = np.zeros(nlist + 1, np.int64)
    offsets[1:] = np.cumsum(sizes)
    # skew: everyone probes cell 0; second slot spreads over 4 cells
    probe = np.stack([np.array([0, 1 + int(rng.integers(0, 4))])
                      for _ in range(q)]).astype(np.int32)
    factor = 2.0
    routing, stats = build_dispatch(probe, offsets, chunk=8,
                                    capacity_factor=factor)
    e_count, cap_needed, _ = stats
    limit = max(1, -(-int(factor * q * probe.shape[1]) // e_count))
    if routing is None:
        assert cap_needed > limit  # refused exactly when over budget
    else:
        per_cell = (np.asarray(routing.plan.qidx) >= 0).sum(axis=1)
        assert per_cell.max() <= limit
        assert int(routing.overflow) == 0

    # a factor too small for the skew must refuse (loud fallback), and
    # the search-level fallback must stay bit-identical to padded
    tight, stats2 = build_dispatch(probe, offsets, chunk=8,
                                   capacity_factor=0.05)
    assert tight is None and stats2[1] > 0


def test_capacity_overflow_falls_back_loudly():
    """Search with an overflowing capacity factor warns and returns the
    padded path's exact results — dropped probes are never silent."""
    rng = np.random.default_rng(4)
    xs = rng.standard_normal((300, 8)).astype(np.float32)
    queries = np.repeat(xs[:1], 16, axis=0)        # maximal probe skew
    ivf = index_factory("IVF8,PQ2x16", 8, backend="xla")
    ivf.train(xs, iters=3)
    ivf.add(xs)
    want_d, want_i = ivf.search(queries, 5, use_dispatch=False)
    ivf.dispatch_capacity = 0.01
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got_d, got_i = ivf.search(queries, 5, use_dispatch=True)
    assert any("capacity overflow" in str(w.message) for w in caught)
    np.testing.assert_array_equal(np.asarray(want_d), np.asarray(got_d))
    np.testing.assert_array_equal(np.asarray(want_i), np.asarray(got_i))


def test_route_stats_and_bucketing():
    """The router's measurements are exact (distinct cells, true max
    co-probe batch, chunk-aligned tile count) and the compiled shapes
    bucket on powers of two."""
    offsets = np.array([0, 10, 10, 25, 100], np.int64)
    probe = np.array([[0, 2], [0, 3], [2, 3]], np.int32)
    e, cap, t = route_stats(probe, offsets, chunk=8)
    assert e == 3                                  # cells {0, 2, 3}
    assert cap == 2                                # cells 0/2/3 twice max
    # chunk-ALIGNED tiles: cell0 [0,10) -> 2; cell2 [10,25) starts at
    # block 1 so spans 17 rows -> 3; cell3 [25,100) starts at block 3,
    # spans 76 rows -> 10
    assert t == 2 + 3 + 10
    routing, _ = build_dispatch(probe, offsets, chunk=8)
    assert routing.plan.qidx.shape[0] - 1 in (4, 8)     # pow2 bucket
    assert routing.plan.qidx.shape[1] in (8,)           # floor bucket


def test_probe_plan_flat_sort_matches_per_row_argsort():
    """Satellite regression: the single flat lexsort plan builder emits
    exactly what the original per-row ``np.argsort(gids, axis=1)``
    construction produced, and repeated probes hit the memo."""
    rng = np.random.default_rng(5)
    xs = rng.standard_normal((400, 8)).astype(np.float32)
    ivf = index_factory("IVF8,PQ2x16", 8, backend="xla")
    ivf.train(xs, iters=3)
    ivf.add(xs)
    probe = ivf.probe_cells(xs[:7], 3)
    rows, gids, cells = ivf._probe_plan(probe)
    # reference: scatter unsorted, then the old padded per-row argsort
    off, ids_np, cells_np = ivf._offsets, ivf._ids_np, ivf._cells_np
    for qi in range(probe.shape[0]):
        r = np.concatenate([np.arange(off[c], off[c + 1])
                            for c in probe[qi]]).astype(np.int64)
        g = ids_np[r]
        o = np.argsort(g, kind="stable")
        np.testing.assert_array_equal(rows[qi, :r.size], r[o])
        np.testing.assert_array_equal(gids[qi, :r.size], g[o])
        np.testing.assert_array_equal(cells[qi, :r.size], cells_np[r[o]])
        assert (gids[qi, r.size:] == _IMAX).all()
    assert ivf._probe_plan(probe) is (rows, gids, cells) \
        or ivf._probe_plan(probe)[0] is rows       # memo hit
    ivf.add(xs[:5])                                # mutation drops the memo
    assert not ivf._plan_cache


@settings(max_examples=6, deadline=None)
@given(
    nlist=st.integers(2, 10),
    nprobe=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_search_dispatch_padded_agree_property(nlist, nprobe, seed):
    """Hypothesis property (alongside the test_ivf partition/filter
    properties): for random tie-heavy indexes, probe widths and filters,
    the dispatch and padded faces agree bit-for-bit."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(30, 300))
    q = int(rng.integers(1, 6))
    xs = rng.integers(0, 3, size=(n, 8)).astype(np.float32)
    queries = rng.integers(0, 3, size=(q, 8)).astype(np.float32)
    ivf = index_factory(f"IVF{nlist},PQ2x16", 8, backend="xla")
    ivf.train(xs, iters=3)
    ivf.add(xs)
    mask = rng.random(n) > 0.5 if rng.integers(0, 2) else None
    k = int(rng.integers(1, 15))
    d_pad, i_pad = ivf.search(queries, k, nprobe=nprobe, filter_mask=mask,
                              use_dispatch=False)
    d_dis, i_dis = ivf.search(queries, k, nprobe=nprobe, filter_mask=mask,
                              use_dispatch=True)
    np.testing.assert_array_equal(np.asarray(d_pad), np.asarray(d_dis))
    np.testing.assert_array_equal(np.asarray(i_pad), np.asarray(i_dis))


def test_build_shard_dispatch_clip_offsets():
    """The sharded router's clip-restricted offsets make non-owned cells
    empty spans (no probe masking), keep global cell alignment, and share
    one set of shape buckets across shards."""
    rng = np.random.default_rng(6)
    nlist = 8
    sizes = rng.integers(1, 30, size=nlist)
    offsets = np.zeros(nlist + 1, np.int64)
    offsets[1:] = np.cumsum(sizes)
    bounds = [0, 3, 6, 8]
    probe = np.stack([rng.choice(nlist, size=3, replace=False)
                      for _ in range(5)]).astype(np.int32)
    routings = build_shard_dispatch(probe, offsets, bounds, chunk=8)
    assert len(routings) == 3
    shapes = {(r.plan.qidx.shape, r.plan.tile_e.shape) for r in routings}
    assert len(shapes) == 1                        # common buckets
    for s, routing in enumerate(routings):
        lo_cell, hi_cell = bounds[s], bounds[s + 1]
        cell_of = np.asarray(routing.cell_of)
        lo = np.asarray(routing.cell_lo)
        hi = np.asarray(routing.cell_hi)
        for e in range(cell_of.shape[0]):
            c = cell_of[e]
            if c < 0:
                continue
            if lo_cell <= c < hi_cell:             # owned: true local span
                assert hi[e] - lo[e] == sizes[c]
            else:                                  # foreign: empty span
                assert hi[e] == lo[e]
