"""Shallow MCQ baselines: k-means, PQ, OPQ, RVQ (paper's comparison set)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core.search import recall_at_k
from repro.data.descriptors import exact_knn


@pytest.fixture(scope="module")
def data(tiny_dataset):
    return (jnp.asarray(tiny_dataset.train), jnp.asarray(tiny_dataset.base),
            jnp.asarray(tiny_dataset.queries),
            jnp.asarray(tiny_dataset.gt_nn))


def _distortion(x, recon):
    return float(jnp.mean(jnp.sum(jnp.square(x - recon), axis=-1)))


def test_kmeans_reduces_distortion(data):
    train, *_ = data
    key = jax.random.PRNGKey(0)
    x = train[:800]
    c1 = bl.kmeans(key, x, 16, iters=1)
    c25 = bl.kmeans(key, x, 16, iters=25)
    d1 = _distortion(x, c1[bl._assign(x, c1)])
    d25 = _distortion(x, c25[bl._assign(x, c25)])
    assert d25 <= d1 * 1.01


def test_pq_roundtrip_and_recall(data):
    train, base, queries, gt = data
    model = bl.train_pq(jax.random.PRNGKey(0), train, num_books=8,
                        book_size=32, iters=8)
    codes = model.encode(base)
    assert codes.shape == (base.shape[0], 8) and codes.dtype == jnp.uint8
    dist = _distortion(base, model.decode(codes))
    base_var = _distortion(base, jnp.mean(base, 0, keepdims=True))
    assert dist < base_var * 0.9          # better than the mean predictor
    got = bl.search_pq(model, queries[:100], codes, topk=100)
    rec = recall_at_k(got, gt[:100])
    assert rec["recall@100"] > 0.3, rec   # far above random (100/4000)


def test_opq_rotation_is_orthogonal_and_helps(data):
    train, base, queries, gt = data
    key = jax.random.PRNGKey(1)
    pq = bl.train_pq(key, train, num_books=4, book_size=32, iters=8)
    opq = bl.train_opq(key, train, num_books=4, book_size=32,
                       outer_iters=4, kmeans_iters=6)
    r = np.asarray(opq.rotation)
    np.testing.assert_allclose(r @ r.T, np.eye(r.shape[0]), atol=1e-4)
    d_pq = _distortion(train, pq.decode(pq.encode(train)))
    d_opq = _distortion(train, opq.decode(opq.encode(train)))
    assert d_opq <= d_pq * 1.05           # OPQ >= PQ (allow tie + noise)


def test_rvq_distortion_decreases_with_depth(data):
    train, *_ = data
    key = jax.random.PRNGKey(2)
    prev = None
    for m in (1, 2, 4):
        model = bl.train_rvq(key, train, num_books=m, book_size=32, iters=8)
        d = _distortion(train, model.decode(model.encode(train)))
        if prev is not None:
            assert d <= prev * 1.01, (m, d, prev)
        prev = d


def test_rvq_adc_search_matches_decoded_distances(data):
    """ADC-with-norms must rank identically to exact reconstruction dists."""
    train, base, queries, _ = data
    model = bl.train_rvq(jax.random.PRNGKey(3), train[:600], num_books=4,
                         book_size=16, iters=6)
    codes = model.encode(base[:500])
    recon = model.decode(codes)
    norms = jnp.sum(recon * recon, axis=-1)
    got = bl.search_rvq(model, queries[:10], codes, norms, topk=20)
    for i in range(10):
        d_exact = jnp.sum(jnp.square(recon - queries[i]), axis=-1)
        want = np.asarray(jax.lax.top_k(-d_exact, 20)[1])
        assert set(np.asarray(got[i]).tolist()) == set(want.tolist())


def test_rerank_decoder_reduces_reconstruction_error(data):
    train, *_ = data
    model = bl.train_pq(jax.random.PRNGKey(4), train, num_books=4,
                        book_size=16, iters=6)
    recon = model.decode(model.encode(train))
    params, apply_fn = bl.train_rerank_decoder(
        jax.random.PRNGKey(5), recon, train, hidden=128, steps=1000)
    improved = apply_fn(params, recon)
    assert _distortion(train, improved) < _distortion(train, recon)
