"""IVF coarse partitioning + filtered search: the acceptance properties.

  * gathered scan+top-L kernels (oracle / chunked xla / fused pallas in
    interpret mode) agree bit-for-bit on random ragged plans, ties,
    pads and +inf-filtered slots;
  * ``IVF*`` indexes at ``nprobe == nlist`` are bit-identical to flat
    search — scores AND indices — on every backend, tie-heavy data
    included, and ``filter_mask`` results match an index built over only
    the kept points exactly;
  * edge cases: empty cells, singleton cells, ``nprobe > nlist``,
    all-masked queries, pools smaller than k (-1/+inf padding);
  * recall is monotone in nprobe (within a tie tolerance) and lands
    exactly on flat recall at full probe;
  * by-cell sharding (host mode) reproduces the flat IVF result and
    skips shards no query probes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import baselines as bl
from repro.core.search import recall_at_k
from repro.index import (IVFIndex, Index, ShardedIndex, index_factory,
                         merge_topl)
from repro.kernels import ops, ref

_IMAX = np.iinfo(np.int32).max


def _random_partition_plan(rng, n, nlist, probe_cells, q):
    """A random cell partition of n points plus the (rows, gids) plan for
    ``probe_cells[q]`` per query — the ground-truth construction the
    IVFIndex CSR plan builder must reproduce."""
    cells = rng.integers(0, nlist, n)
    order = np.argsort(cells, kind="stable")       # buffer grouping
    ids = order.astype(np.int32)                   # buffer row -> global id
    w = 0
    plans = []
    for qi in range(q):
        in_probe = np.isin(cells[order], probe_cells[qi])
        rows = np.flatnonzero(in_probe).astype(np.int32)
        gids = ids[rows]
        o = np.argsort(gids, kind="stable")        # plan contract
        plans.append((rows[o], gids[o]))
        w = max(w, rows.size)
    w = max(w, 1)
    rows = np.zeros((q, w), np.int32)
    gids = np.full((q, w), _IMAX, np.int32)
    for qi, (r, g) in enumerate(plans):
        rows[qi, :r.size] = r
        gids[qi, :g.size] = g
    return order, rows, gids


# ---------------------------------------------------------------------------
# kernel-level properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 400),
    nlist=st.integers(1, 24),
    L=st.integers(1, 80),
    block_w=st.sampled_from([32, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_topl_full_probe_equals_flat(scan_case, n, nlist, L,
                                            block_w, seed):
    """Property: scanning a randomly cell-grouped buffer through the
    per-query plan of ALL cells is bit-identical — scores and ids — to
    the flat streaming scan of the original database, on the oracle, the
    chunked xla path and the fused kernel (interpret mode)."""
    rng = np.random.default_rng(seed)
    q = int(rng.integers(1, 6))
    codes, luts = scan_case(rng, n, m=4, k=16, q=q,
                            tie_heavy=bool(rng.integers(0, 2)))
    bias = (jnp.asarray(rng.integers(-1, 2, (n,)), jnp.float32)
            if rng.integers(0, 2) else None)
    want_s, want_i = ref.adc_scan_topl_ref(codes, luts, bias, L)

    probe = np.broadcast_to(np.arange(nlist), (q, nlist))
    order, rows, gids = _random_partition_plan(rng, n, nlist, probe, q)
    buf = jnp.take(codes, jnp.asarray(order), axis=0)
    rowbias = None if bias is None else \
        jnp.take(jnp.asarray(bias), jnp.where(jnp.asarray(gids) == _IMAX, 0,
                                              jnp.asarray(gids)))
    got_ref = ref.adc_gather_topl_ref(buf, jnp.asarray(rows),
                                      jnp.asarray(gids), luts, rowbias, L)
    np.testing.assert_array_equal(np.asarray(got_ref[0]),
                                  np.asarray(want_s), err_msg="oracle s")
    np.testing.assert_array_equal(np.asarray(got_ref[1]),
                                  np.asarray(want_i), err_msg="oracle i")
    for impl in ("xla", "pallas"):
        got = ops.adc_gather_topl(
            buf, jnp.asarray(rows), jnp.asarray(gids), luts, topl=L,
            rowbias=rowbias, impl=impl, block_w=block_w,
            chunk_w=max(1, block_w // 2))
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want_s), err_msg=impl)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want_i), err_msg=impl)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 300),
    L=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_topl_partial_and_filtered_parity(scan_case, n, L, seed):
    """Property: on PARTIAL probes with random +inf-filtered slots, the
    streaming gather paths agree bit-for-bit with the materialized
    oracle, including the canonical (+inf, _IMAX) pads when fewer than L
    real slots survive."""
    rng = np.random.default_rng(seed)
    q = int(rng.integers(1, 6))
    nlist = int(rng.integers(1, 12))
    codes, luts = scan_case(rng, n, m=4, k=16, q=q,
                            tie_heavy=bool(rng.integers(0, 2)))
    nprobe = int(rng.integers(1, nlist + 1))
    probe = np.stack([rng.choice(nlist, nprobe, replace=False)
                      for _ in range(q)])
    order, rows, gids = _random_partition_plan(rng, n, nlist, probe, q)
    buf = jnp.take(codes, jnp.asarray(order), axis=0)
    rowbias = jnp.where(jnp.asarray(rng.integers(0, 4, rows.shape)) == 0,
                        jnp.inf, 0.0)
    want = ref.adc_gather_topl_ref(buf, jnp.asarray(rows),
                                   jnp.asarray(gids), luts, rowbias, L)
    for impl in ("xla", "pallas"):
        got = ops.adc_gather_topl(
            buf, jnp.asarray(rows), jnp.asarray(gids), luts, topl=L,
            rowbias=rowbias, impl=impl, block_w=64, chunk_w=48)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]), err_msg=impl)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]), err_msg=impl)
    # masked slots never surface: per query, every finite result id is
    # one of that query's unfiltered slots
    rb_np, gids_np = np.asarray(rowbias), np.asarray(gids)
    for qi, (s_row, i_row) in enumerate(zip(np.asarray(want[0]),
                                            np.asarray(want[1]))):
        dropped = set(gids_np[qi][np.isinf(rb_np[qi])
                                  & (gids_np[qi] != _IMAX)].tolist())
        for s, i in zip(s_row, i_row):
            if np.isfinite(s):
                assert i not in dropped, qi


def test_merge_topl_is_lexicographic():
    """Cross-shard merge: exact (score, id) lexicographic top-L over an
    unsorted tie-heavy pool, +inf canonicalized to _IMAX."""
    rng = np.random.default_rng(0)
    scores = rng.integers(-3, 3, (7, 40)).astype(np.float32)
    scores[scores > 1.5] = np.inf
    ids = rng.permutation(7 * 40).reshape(7, 40).astype(np.int32)
    s, g = merge_topl(jnp.asarray(scores), jnp.asarray(ids), 10)
    for qi in range(7):
        pairs = sorted((float(sv), _IMAX if np.isinf(sv) else int(iv))
                       for sv, iv in zip(scores[qi], ids[qi]))
        want = pairs[:10]
        got = list(zip(np.asarray(s)[qi].tolist(),
                       np.asarray(g)[qi].tolist()))
        assert got == want, qi


# ---------------------------------------------------------------------------
# index-level: full probe == flat, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant,nlist", [("PQ4x32", 16), ("RVQ2x32", 8)])
def test_ivf_full_probe_bit_exact_vs_flat(ivf_flat_pair, quant, nlist):
    """Acceptance: IVF(nprobe=nlist) == flat search bit-for-bit (scores
    and indices) on xla, pallas-interpret AND onehot, with and without
    rerank — RVQ included so the per-point bias threads the plan."""
    ivf, flat = ivf_flat_pair(quant, nlist, rerank=50, iters=4)
    queries = jnp.asarray(np.random.default_rng(0).normal(
        size=(20, flat.dim)).astype(np.float32))
    for backend in ("xla", "pallas", "onehot"):
        ivf.backend = backend
        flat.backend = backend
        for kw in (dict(), dict(use_rerank=False)):
            dw, iw = flat.search(queries, 15, **kw)
            dg, ig = ivf.search(queries, 15, nprobe=nlist, **kw)
            np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw),
                                          err_msg=f"{backend} {kw} idx")
            np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw),
                                          err_msg=f"{backend} {kw} d")


def _integer_pair(rng, n, dim=16, m=4, k=8, nlist=6, rerank=30):
    """A hand-built PQ/IVF pair over INTEGER codebooks, centroids and
    data: d2 and d1 collisions are ubiquitous, so parity is a test of
    tie resolution end to end (no training involved)."""
    books = jnp.asarray(rng.integers(-2, 3, (m, k, dim // m)), jnp.float32)
    flat = index_factory(f"PQ{m}x{k},Rerank{rerank}", dim=dim)
    flat.model = bl.PQModel(books)
    ivf = index_factory(f"IVF{nlist},PQ{m}x{k},Rerank{rerank}", dim=dim)
    ivf.inner.model = bl.PQModel(books)
    ivf.coarse = jnp.asarray(rng.integers(-2, 3, (nlist, dim)), jnp.float32)
    data = rng.integers(-2, 3, (n, dim)).astype(np.float32)
    flat.add(data)
    ivf.add(data)
    return ivf, flat, data


def test_ivf_tie_heavy_full_probe_parity():
    rng = np.random.default_rng(3)
    ivf, flat, _ = _integer_pair(rng, n=500)
    queries = jnp.asarray(rng.integers(-2, 3, (16, flat.dim)), jnp.float32)
    # sanity: the data really is tie-heavy at stage 1
    scores = np.asarray(ref.adc_scan_batch_ref(flat.codes,
                                               flat._build_luts(queries)))
    assert np.mean(np.diff(np.sort(scores, axis=1), axis=1) == 0) > 0.5
    for backend in ("xla", "pallas", "onehot"):
        ivf.backend = backend
        flat.backend = backend
        for kw in (dict(), dict(use_rerank=False)):
            dw, iw = flat.search(queries, 20, **kw)
            dg, ig = ivf.search(queries, 20, nprobe=ivf.nlist, **kw)
            np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw),
                                          err_msg=f"{backend} {kw}")
            np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw),
                                          err_msg=f"{backend} {kw}")


# ---------------------------------------------------------------------------
# edge cases: empty cells, singletons, nprobe > nlist, tiny pools
# ---------------------------------------------------------------------------

def test_ivf_empty_and_singleton_cells():
    """nlist far above the point count: most cells empty, occupied ones
    near-singletons — full probe still reproduces flat search, partial
    probes still return well-formed results."""
    rng = np.random.default_rng(1)
    ivf, flat, _ = _integer_pair(rng, n=40, nlist=64)
    lens = np.diff(ivf._offsets)
    assert (lens == 0).sum() > 0, "expected empty cells"
    queries = jnp.asarray(rng.integers(-2, 3, (9, flat.dim)), jnp.float32)
    dw, iw = flat.search(queries, 10)
    dg, ig = ivf.search(queries, 10, nprobe=64)
    np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw))
    # nprobe > nlist clamps instead of erroring
    dg2, ig2 = ivf.search(queries, 10, nprobe=1000)
    np.testing.assert_array_equal(np.asarray(ig2), np.asarray(iw))
    # a 1-cell probe may underfill the pool: the result still has the
    # flat-search width min(k, ntotal), tail is (-1, +inf), never junk
    d, i = ivf.search(queries, 30, nprobe=1)
    d, i = np.asarray(d), np.asarray(i)
    assert d.shape == i.shape == (9, 30)
    assert ((i >= 0) == np.isfinite(d)).all()
    assert (i[np.isfinite(d)] < ivf.ntotal).all()
    # within each row, -1 pads trail the real results
    for row in np.isfinite(d):
        assert not (~row[:-1] & row[1:]).any()


def test_ivf_add_regroups_incrementally(ivf_flat_pair):
    """Chunked adds land in the same cells as one big add: the buffer is
    regrouped per add and search results stay identical (global ids are
    assignment order, independent of the grouping)."""
    ivf, flat = ivf_flat_pair("PQ4x32", 16, rerank=50, iters=4)
    rng = np.random.default_rng(2)
    data = rng.normal(size=(500, flat.dim)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(8, flat.dim)), jnp.float32)
    one = IVFIndex(flat.dim, inner=ivf.inner, nlist=16, nprobe=4, rerank=50)
    one.coarse = ivf.coarse
    one.add(data)
    chunked = IVFIndex(flat.dim, inner=ivf.inner, nlist=16, nprobe=4,
                       rerank=50)
    chunked.coarse = ivf.coarse
    for lo, hi in ((0, 100), (100, 101), (101, 500)):
        chunked.add(data[lo:hi])
    np.testing.assert_array_equal(chunked._ids_np, one._ids_np)
    np.testing.assert_array_equal(chunked._offsets, one._offsets)
    for nprobe in (2, 16):
        dw, iw = one.search(queries, 12, nprobe=nprobe)
        dg, ig = chunked.search(queries, 12, nprobe=nprobe)
        np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw))
        np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw))


# ---------------------------------------------------------------------------
# filter_mask: never surfaces masked ids, exact subset semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["PQ4x32,Rerank50", "RVQ2x32,Rerank50"])
def test_filter_mask_matches_subset_index(trained_index_factory, spec):
    """Acceptance: filtered flat search == searching an index that only
    contains the kept points (same trained quantizer), distances and
    (remapped) indices bit-for-bit — rerank on and off."""
    index = trained_index_factory(spec, iters=4)
    rng = np.random.default_rng(0)
    queries = jnp.asarray(rng.normal(size=(15, index.dim)), jnp.float32)
    mask = rng.integers(0, 2, index.ntotal).astype(bool)
    keep = np.flatnonzero(mask)
    sub = index.with_codes(
        index.codes[jnp.asarray(keep)],
        None if index.bias is None else index.bias[jnp.asarray(keep)])
    for backend in ("xla", "pallas", "onehot"):
        index.backend = backend
        sub.backend = backend
        for kw in (dict(), dict(use_rerank=False)):
            df, iff = index.search(queries, 12, filter_mask=mask, **kw)
            dsb, isb = sub.search(queries, 12, **kw)
            np.testing.assert_array_equal(np.asarray(iff),
                                          keep[np.asarray(isb)],
                                          err_msg=f"{backend} {kw}")
            np.testing.assert_array_equal(np.asarray(df), np.asarray(dsb),
                                          err_msg=f"{backend} {kw}")


def test_filter_mask_per_query_and_ivf(trained_index_factory):
    """Per-query masks and the IVF plan lowering: a masked id never
    surfaces from any path, a fully-masked query reports all (-1, +inf),
    and full-probe filtered IVF equals filtered flat search exactly."""
    flat = trained_index_factory("PQ4x32,Rerank50", iters=4)
    ivf = trained_index_factory("IVF16,PQ4x32,Rerank50", iters=4)
    rng = np.random.default_rng(4)
    q = 10
    queries = jnp.asarray(rng.normal(size=(q, flat.dim)), jnp.float32)
    maskq = rng.integers(0, 2, (q, flat.ntotal)).astype(bool)
    maskq[3, :] = False                       # one fully-masked query
    df, iff = flat.search(queries, 12, filter_mask=maskq)
    iff = np.asarray(iff)
    for qi in range(q):
        for x in iff[qi]:
            assert x == -1 or maskq[qi, x], (qi, x)
    assert (iff[3] == -1).all() and np.isinf(np.asarray(df)[3]).all()
    # shared (N,) mask: IVF full probe == flat, masked ids never surface
    mask = rng.integers(0, 2, flat.ntotal).astype(bool)
    dw, iw = flat.search(queries, 12, filter_mask=mask)
    dg, ig = ivf.search(queries, 12, nprobe=16, filter_mask=mask)
    np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw))
    d, i = ivf.search(queries, 12, nprobe=3, filter_mask=mask)
    for x in np.asarray(i).ravel():
        assert x == -1 or mask[x]
    # per-query masks lower into the IVF plan too
    dgq, igq = ivf.search(queries, 12, nprobe=16, filter_mask=maskq)
    dfq, ifq = flat.search(queries, 12, filter_mask=maskq)
    np.testing.assert_array_equal(np.asarray(igq), np.asarray(ifq))
    np.testing.assert_array_equal(np.asarray(dgq), np.asarray(dfq))


def test_filter_mask_shape_validation(trained_index_factory):
    index = trained_index_factory("PQ4x32,Rerank50", iters=4)
    queries = jnp.zeros((3, index.dim), jnp.float32)
    with pytest.raises(ValueError, match="filter_mask shape"):
        index.search(queries, 5, filter_mask=np.ones(7, bool))
    with pytest.raises(ValueError, match="filter_mask shape"):
        index.search(queries, 5,
                     filter_mask=np.ones((5, index.ntotal), bool))
    with pytest.raises(ValueError, match="use_d2"):
        index.search(queries, 5, use_d2=False,
                     filter_mask=np.ones(index.ntotal, bool))


# ---------------------------------------------------------------------------
# recall trajectory + sharding
# ---------------------------------------------------------------------------

def test_recall_monotone_in_nprobe(tiny_dataset, trained_index_factory):
    """The nprobe dial. Two guarantees, one strict and one statistical:

    * STRICTLY monotone: per-query top-nprobe probe sets are prefix-
      nested, so "the true neighbor's cell is probed" can only switch
      False -> True as nprobe grows — coverage recall is exactly
      non-decreasing.
    * end-to-end recall@10 is non-decreasing up to a small tolerance
      (a FIXED rerank budget means extra probed cells can evict the
      true neighbor from the top-L d2 pool — the classic L/nprobe
      trade-off, tracked, not hidden) and lands EXACTLY on flat search
      at nprobe == nlist.
    """
    ivf = trained_index_factory("IVF16,PQ4x32,Rerank50", iters=4)
    flat = trained_index_factory("PQ4x32,Rerank50", iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:80])
    gt_np = np.asarray(tiny_dataset.gt_nn[:80])
    gt = jnp.asarray(gt_np)
    gt_cells = ivf._cells_np[np.asarray(
        jnp.take(ivf._pos_dev, jnp.asarray(gt_np)))]   # true NN's cell
    prev_cov, peak = -1.0, -1.0
    recalls, coverage = [], []
    for nprobe in (1, 2, 4, 8, 16):
        probe = ivf.probe_cells(queries, nprobe)
        cov = float(np.mean([gt_cells[i] in probe[i]
                             for i in range(len(gt_np))]))
        coverage.append(cov)
        assert cov >= prev_cov, (nprobe, coverage)     # strict
        prev_cov = cov
        _, got = ivf.search(queries, 10, nprobe=nprobe)
        rec = recall_at_k(got, gt, ks=(10,))["recall@10"]
        recalls.append(round(rec, 3))
        assert rec >= peak - 0.03, (nprobe, recalls)
        assert rec <= cov + 1e-9, (nprobe, recalls, coverage)
        peak = max(peak, rec)
    assert coverage[-1] == 1.0                          # full probe
    _, flat_got = flat.search(queries, 10)
    flat_rec = recall_at_k(flat_got, gt, ks=(10,))["recall@10"]
    assert recalls[-1] == round(flat_rec, 3)
    assert recalls[-1] > 0.2, recalls      # the trained index is not junk


def test_sharded_ivf_matches_flat_ivf(trained_index_factory):
    """By-cell host sharding: same results as the unsharded IVF index for
    every nprobe, and shards outside the probed cells are skipped."""
    ivf = trained_index_factory("IVF16,RVQ2x32,Rerank50", iters=4)
    rng = np.random.default_rng(5)
    queries = jnp.asarray(rng.normal(size=(10, ivf.dim)), jnp.float32)
    for num_shards in (1, 3, 5):
        sharded = ShardedIndex(ivf, num_shards=num_shards)
        assert sharded.resolved_placement == "host"
        for nprobe in (1, 4, 16):
            dw, iw = ivf.search(queries, 12, nprobe=nprobe)
            dg, ig = sharded.search(queries, 12, nprobe=nprobe)
            np.testing.assert_array_equal(
                np.asarray(ig), np.asarray(iw),
                err_msg=f"shards={num_shards} nprobe={nprobe}")
            np.testing.assert_array_equal(
                np.asarray(dg), np.asarray(dw),
                err_msg=f"shards={num_shards} nprobe={nprobe}")
    # a probe hitting one cell leaves the other shards' plans empty
    sharded = ShardedIndex(ivf, num_shards=4)
    bounds = sharded._ivf_cell_bounds()
    assert bounds[0] == 0 and bounds[-1] == ivf.nlist
    assert all(b <= c for b, c in zip(bounds, bounds[1:]))
    with pytest.raises(ValueError, match="from_shards"):
        ShardedIndex.from_shards(ivf, [ivf.codes], [0])


def test_sharded_host_filter_threading(trained_index_factory):
    """Host-mode sharded filtered search == flat filtered search — per
    query (Q, N) masks on a BIAS-LESS index included (regression: the
    per-shard bias slice used to assume a per-point bias existed)."""
    rng = np.random.default_rng(8)
    for spec in ("PQ4x32,Rerank50", "RVQ2x32,Rerank50"):
        index = trained_index_factory(spec, iters=4)
        queries = jnp.asarray(rng.normal(size=(9, index.dim)), jnp.float32)
        sharded = ShardedIndex(index, num_shards=3)
        assert sharded.resolved_placement == "host"
        for mask in (rng.integers(0, 2, index.ntotal).astype(bool),
                     rng.integers(0, 2, (9, index.ntotal)).astype(bool)):
            dw, iw = index.search(queries, 12, filter_mask=mask)
            dg, ig = sharded.search(queries, 12, filter_mask=mask)
            np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw),
                                          err_msg=f"{spec} {mask.ndim}d")
            np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw),
                                          err_msg=f"{spec} {mask.ndim}d")
        # raw stage-1 pools keep the _IMAX sentinel on +inf slots (no
        # wrapped "global" ids from the shard offset add)
        tiny = np.zeros(index.ntotal, bool)
        tiny[:4] = True
        s, ids = sharded.stage1_candidates(queries, topl=20,
                                           filter_mask=tiny)
        ids = np.asarray(ids)
        bad = ~np.isfinite(np.asarray(s))
        assert (ids[bad] == np.iinfo(np.int32).max).all()
        assert ((ids[~bad] >= 0) & (ids[~bad] < index.ntotal)).all()


def test_ivf_view_guards(trained_index_factory):
    ivf = trained_index_factory("IVF16,PQ4x32,Rerank50", iters=4)
    with pytest.raises(NotImplementedError):
        ivf.subset(10)
    with pytest.raises(NotImplementedError):
        ivf.with_codes(ivf.codes)
    with pytest.raises(ValueError, match="NProbe"):
        index_factory("PQ4x32,NProbe8", dim=32)
    with pytest.raises(ValueError, match="multiple IVF"):
        index_factory("IVF8,IVF16,PQ4x32", dim=32)


def test_ivf_exhaustive_ablation_matches_flat(ivf_flat_pair):
    """use_d2=False ranks the whole database by exact d1 — identical for
    IVF and flat indexes over the same vectors (add-order view)."""
    ivf, flat = ivf_flat_pair("PQ4x32", 16, rerank=50, iters=4)
    queries = jnp.asarray(np.random.default_rng(6).normal(
        size=(6, flat.dim)), jnp.float32)
    dw, iw = flat.search(queries, 10, use_d2=False)
    dg, ig = ivf.search(queries, 10, use_d2=False)
    np.testing.assert_array_equal(np.asarray(ig), np.asarray(iw))
    np.testing.assert_array_equal(np.asarray(dg), np.asarray(dw))
