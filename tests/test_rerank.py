"""Streaming stage-2 engine: fused gather-decode-distance kernel vs
chunked xla fallback vs the materialized vmap oracle — exact d1 parity
including tie semantics and cross-query duplicate candidates — plus the
HLO no-(Q, L, D)/(Q, N, D)-buffer guarantees, reranker resolution through
the capability matrix, the ``use_d2=False`` chunked exhaustive rerank,
and the bucket-padded ``add`` satellite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.analysis.contracts import assert_contract
from repro.index import (DedupRerank, TableRerank, VmapRerank,
                         backend_supports, candidate_generator_for,
                         reranker_for)
from repro.kernels import ops, ref


# tie-heavy case construction lives in conftest (``rerank_case``):
# integer tables + rounded queries make d1 collisions ubiquitous, so
# downstream top-k parity tests tie RESOLUTION, not just math


# ---------------------------------------------------------------------------
# kernel-level parity: fused vs chunked vs materialized oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tie_heavy", [False, True])
@pytest.mark.parametrize("q,l,d", [(5, 77, 24),      # L % block/chunk != 0
                                   (8, 500, 96),     # paper-ish shape
                                   (1, 1, 8),        # degenerate
                                   (3, 130, 96)])
def test_rerank_gather_dist_all_impls_bit_exact(rerank_case, q, l, d,
                                                tie_heavy):
    rng = np.random.default_rng(q * l + d)
    cand, queries, table = rerank_case(rng, q, l, m=4, k=32, d=d,
                                       tie_heavy=tie_heavy)
    want = jax.jit(ref.rerank_gather_dist_ref)(cand, queries, table)
    assert want.shape == (q, l)
    for impl in ("xla", "pallas"):
        got = ops.rerank_gather_dist(cand, queries, table, impl=impl,
                                     block_l=16, chunk_l=13)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=impl)


def test_duplicate_candidates_across_queries():
    """Stage-1 pools overlap across queries (and L > N duplicates within
    a pool): every path must score each duplicate occurrence identically."""
    rng = np.random.default_rng(0)
    n, m, k, d, q, l = 40, 4, 16, 24, 6, 120      # L > N: forced duplicates
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    table = jnp.asarray(rng.integers(-2, 3, (m, k, d)), jnp.float32)
    queries = jnp.asarray(np.round(rng.normal(size=(q, d))), jnp.float32)
    cand_rows = jnp.asarray(rng.integers(0, n, (q, l)), jnp.int32)
    cand = jnp.take(codes, cand_rows, axis=0)
    want = jax.jit(ref.rerank_gather_dist_ref)(cand, queries, table)
    for impl in ("xla", "pallas"):
        got = ops.rerank_gather_dist(cand, queries, table, impl=impl,
                                     block_l=32, chunk_l=48)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=impl)
    # duplicated candidate columns carry identical distances
    flat = np.asarray(want)
    rows = np.asarray(cand_rows)
    for i in range(q):
        _, first = np.unique(rows[i], return_index=True)
        lut = {rows[i][j]: flat[i][j] for j in first}
        assert all(flat[i][j] == lut[rows[i][j]] for j in range(l))


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(1, 200),
    block_l=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rerank_property_parity(rerank_case, l, block_l, seed):
    """Property: random shapes/blockings/chunkings — fused kernel
    (interpret mode), chunked xla and the materialized oracle agree
    bit-for-bit on d1."""
    rng = np.random.default_rng(seed)
    q = int(rng.integers(1, 7))
    cand, queries, table = rerank_case(rng, q, l, m=4, k=16, d=16,
                                       tie_heavy=bool(rng.integers(0, 2)))
    want = jax.jit(ref.rerank_gather_dist_ref)(cand, queries, table)
    for impl in ("xla", "pallas"):
        got = ops.rerank_gather_dist(cand, queries, table, impl=impl,
                                     block_l=block_l,
                                     chunk_l=max(1, block_l // 2))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=impl)


# ---------------------------------------------------------------------------
# index-level parity: every reranker bit-identical on real indexes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["PQ4x32,Rerank50", "OPQ4x32,Rerank50",
                                  "RVQ2x32,Rerank50"])
def test_table_rerankers_bit_identical_on_index(tiny_dataset,
                                                trained_index_factory, spec):
    index = trained_index_factory(spec, iters=4)
    queries = jnp.asarray(tiny_dataset.queries[:20])
    luts = index._build_luts(queries)
    _, cand = candidate_generator_for("xla").topl(index.codes, luts,
                                                  index.bias, topl=50)
    want = VmapRerank().distances(index, queries, cand)
    for backend in ("xla", "pallas"):
        index.backend = backend
        rr = reranker_for(index)
        assert isinstance(rr, TableRerank) and not rr.materializes_recon
        got = rr.distances(index, queries, cand)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=backend)
    # full search agrees across every backend, (distance, index) bit-exact
    index.backend = "xla"
    want_d, want_i = index.search(queries, 20)
    for backend in ("pallas", "onehot"):
        index.backend = backend
        got_d, got_i = index.search(queries, 20)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=backend)
        np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d),
                                      err_msg=backend)


def test_dedup_rerank_matches_vmap_oracle(tiny_dataset):
    """UNQ's neural decoder goes through cross-query dedup: unique rows
    decoded once, distances gathered back — bit-identical to the per-query
    vmap decode, duplicate-heavy pools included."""
    from repro.core import unq
    from repro.index import UNQIndex

    cfg = unq.UNQConfig(dim=96, num_codebooks=8, codebook_size=64,
                        code_dim=32, hidden_dim=96)
    params, state = unq.init(jax.random.PRNGKey(0), cfg)
    index = UNQIndex.from_trained(params, state, cfg, rerank=60)
    index.add(tiny_dataset.base)
    queries = jnp.asarray(tiny_dataset.queries[:25])
    luts = index._build_luts(queries)
    _, cand = candidate_generator_for("xla").topl(index.codes, luts, None,
                                                  topl=60)
    rr = reranker_for(index)
    assert isinstance(rr, DedupRerank)
    want = VmapRerank().distances(index, queries, cand)
    got = rr.distances(index, queries, cand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # pathological overlap: every query shares one tiny hot set
    hot = jnp.asarray(np.random.default_rng(1).integers(0, 30, (25, 60)),
                      jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(rr.distances(index, queries, hot)),
        np.asarray(VmapRerank().distances(index, queries, hot)))


def test_exhaustive_rerank_chunked_equals_materialized(
        tiny_dataset, trained_index_factory):
    """``use_d2=False`` chunks over N with a running (Q, k) heap — the
    result (distance AND index, ties included) is bit-identical to
    ``lax.top_k`` over the materialized (Q, N) d1 matrix."""
    for spec in ("PQ4x32,Rerank50", "RVQ2x32,Rerank50"):
        index = trained_index_factory(spec, iters=4)
        queries = jnp.asarray(tiny_dataset.queries[:15])
        got_d, got_i = index.search(queries, 25, use_d2=False)
        full = jnp.broadcast_to(jnp.arange(index.ntotal),
                                (queries.shape[0], index.ntotal))
        d1 = index._rerank_distances_vmap(queries, full)
        neg, order = jax.lax.top_k(-d1, 25)
        np.testing.assert_array_equal(np.asarray(got_d), np.asarray(-neg),
                                      err_msg=spec)
        np.testing.assert_array_equal(
            np.asarray(got_i),
            np.asarray(jnp.take_along_axis(full, order, axis=1)),
            err_msg=spec)


# ---------------------------------------------------------------------------
# HLO guarantees: no (Q, L, D) / (Q, N, D) reconstruction buffer
# ---------------------------------------------------------------------------

def test_streaming_rerank_contracts():
    """The acceptance guarantee — no (Q, L, D) reconstruction in any
    streaming stage-2 path, temp memory below its footprint — now
    declared ONCE in the contract registry (repro.analysis.contracts)
    and merely invoked here. The vmap control proves the detector sees
    the forbidden buffer where it genuinely exists."""
    assert_contract("stage2.table.xla")
    assert_contract("stage2.fused.pallas")
    assert_contract("stage2.dedup.xla")
    assert_contract("stage2.vmap.control")


def test_exhaustive_rerank_contract():
    """use_d2=False streams over N: no (Q, N, D) reconstruction and no
    (Q, N) distance matrix (declared as stage2.exhaustive.xla)."""
    assert_contract("stage2.exhaustive.xla")


# ---------------------------------------------------------------------------
# capability matrix + reranker resolution
# ---------------------------------------------------------------------------

def test_fused_rerank_capability_and_resolution(trained_index_factory):
    assert backend_supports("pallas", "fused_rerank")
    assert not backend_supports("xla", "fused_rerank")
    assert not backend_supports("onehot", "fused_rerank")

    pq = trained_index_factory("PQ4x32,Rerank50", iters=4)
    pq.rerank = 40
    pq.backend = "pallas"
    rr = reranker_for(pq)
    assert isinstance(rr, TableRerank) and rr.impl == "pallas"
    pq.backend = "xla"
    rr = reranker_for(pq)
    assert isinstance(rr, TableRerank) and rr.impl == "xla"
    pq.backend = "onehot"
    assert isinstance(reranker_for(pq), VmapRerank)


# ---------------------------------------------------------------------------
# satellite: bucket-padded add
# ---------------------------------------------------------------------------

def test_add_bucket_pads_to_fixed_shapes(tiny_dataset,
                                         trained_index_factory):
    """Differently-sized adds reuse one encoder compilation: every
    ``_encode`` call sees a shape from the bucket ladder, and the codes
    are bit-identical to unpadded encoding (encoders are row-stable)."""
    index = trained_index_factory("PQ4x32,Rerank50", iters=4)
    single = index.with_codes(None)
    single.add(tiny_dataset.base)

    seen = []
    chunked = index.with_codes(None)
    real_encode = chunked._encode
    chunked._encode = lambda xs: (seen.append(int(xs.shape[0])),
                                  real_encode(xs))[1]
    for lo, hi in ((0, 100), (100, 350), (350, 351), (351, 4000)):
        chunked.add(tiny_dataset.base[lo:hi])
    assert seen == [256, 256, 256, 4096], seen
    np.testing.assert_array_equal(np.asarray(chunked.codes),
                                  np.asarray(single.codes))
    assert chunked.ntotal == single.ntotal == tiny_dataset.base.shape[0]

    # the ladder continues in 8192 multiples past its last rung
    from repro.index.base import Index
    assert Index._encode_bucket(8193) == 16384
    assert Index._encode_bucket(20000) == 24576
