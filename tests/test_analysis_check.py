"""Static-analysis gate: per-rule good/bad fixtures, the negative
HLO-contract test (a materialized (Q, N) scan must be REJECTED), the
compile-count discipline, and the ``python -m repro.analysis.check`` CLI
(including the seeded-violations inversion CI relies on)."""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts
from repro.analysis.compilecount import count_compiles
from repro.analysis.lint import ALL_RULES, LintTree, run_lint

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "lint"
REPO = pathlib.Path(__file__).resolve().parents[1]


def _tree(which: str) -> LintTree:
    return LintTree(src=FIXTURES / which / "src",
                    tests=FIXTURES / which / "tests")


# ---------------------------------------------------------------------------
# lint rules vs fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ALL_RULES)
def test_each_rule_passes_good_and_flags_bad(rule):
    """Every rule must stay silent on its known-good fixture and fire on
    its known-bad one — a rule that cannot flag its own bad fixture is a
    vacuous gate."""
    assert run_lint(_tree("good"), rules=(rule,)) == []
    bad = run_lint(_tree("bad"), rules=(rule,))
    assert bad, f"rule {rule} missed its seeded bad fixture"
    assert all(f.rule == rule for f in bad)


def test_recompile_hazard_catches_scan_bodies_and_all_three_hazards():
    """float() / .item() / np.* must each be flagged, including inside a
    ``lax.scan`` body that has no jit decorator of its own."""
    msgs = [f.message for f in run_lint(_tree("bad"),
                                        rules=("recompile-hazard",))]
    assert any("float(" in m for m in msgs)
    assert any(".item()" in m for m in msgs)
    assert any("np.log" in m for m in msgs)
    assert any("'body'" in m for m in msgs)         # the scan body


def test_pragma_suppresses_findings(tmp_path):
    """``# lint: allow(<rule>)`` on the offending line silences exactly
    that rule."""
    src = tmp_path / "src"
    (src / "index").mkdir(parents=True)
    (tmp_path / "tests").mkdir()
    (src / "index" / "hot.py").write_text(
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.log(x)  # lint: allow(recompile-hazard)\n"
        "def g(x):\n"
        "    return jax.device_get(x)\n")
    tree = LintTree(src=src, tests=tmp_path / "tests")
    findings = run_lint(tree)
    assert [f.rule for f in findings] == ["host-sync"]


def test_repo_tree_is_lint_clean():
    """The live tree must satisfy its own rules (this is the CI gate)."""
    assert run_lint() == []


# ---------------------------------------------------------------------------
# HLO contracts
# ---------------------------------------------------------------------------

def test_negative_contract_rejects_materialized_qn():
    """The detector itself: point the streaming contract's forbid clause
    at the materialized build — the verifier MUST reject it."""
    control = contracts.REGISTRY["stage1.materialized.control"]
    seeded = dataclasses.replace(
        contracts.REGISTRY["stage1.stream.xla"],
        path_id="test.seeded-materialized",
        build=control.build, buckets=control.buckets, max_temp=None)
    res = contracts.verify(seeded)
    kinds = {v.kind for v in res.violations}
    assert "materialization" in kinds, res


def test_require_clause_fails_on_streaming_build():
    """A control contract pointed at a genuinely streaming build must
    report the missing (Q, N) buffer instead of passing vacuously."""
    stream = contracts.REGISTRY["stage1.stream.xla"]
    seeded = dataclasses.replace(
        contracts.REGISTRY["stage1.materialized.control"],
        path_id="test.vacuous-control",
        build=stream.build, buckets=stream.buckets)
    res = contracts.verify(seeded)
    assert any(v.kind == "missing-shape" for v in res.violations), res


def test_forbidden_host_transfer_ops_detected():
    """An outfeed in the compiled module must trip the forbidden-op
    clause (host transfer inside an engine path)."""

    def build(p):
        def f(x):
            jax.debug.print("x0={v}", v=x[0, 0])   # lowers via outfeed/
            return x * 2                           # custom host callback

        x = jax.ShapeDtypeStruct((p["Q"], p["N"]), jnp.float32)
        return jax.jit(f).lower(x).compile()

    c = contracts.Contract(
        path_id="test.host-transfer", description="", build=build,
        buckets=({"Q": 4, "N": 8},),
        forbidden_ops=contracts.HOST_TRANSFER_OPS + ("custom-call",))
    res = contracts.verify(c)
    assert any(v.kind == "forbidden-op" for v in res.violations), res


def test_sharded_contract_declares_collectives():
    c = contracts.REGISTRY["sharded.stage1.device"]
    assert c.collectives == frozenset({"all-gather"})
    res = contracts.check_contract("sharded.stage1.device")
    if len(jax.devices()) < 2:
        assert res.skipped and "devices" in res.reason
    else:
        assert res.ok


# ---------------------------------------------------------------------------
# compile-count discipline
# ---------------------------------------------------------------------------

def test_compile_counter_sees_fresh_compiles_and_cache_hits():
    with count_compiles() as log:
        jax.jit(lambda x: x * 3 + 1)(jnp.arange(17, dtype=jnp.float32))
    assert log.count >= 1

    f = jax.jit(lambda x: x - 2)
    x = jnp.arange(19, dtype=jnp.float32)
    f(x)
    with count_compiles() as log:
        f(x)                                   # identical shapes: cache hit
    assert log.count == 0


# ---------------------------------------------------------------------------
# the CLI
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               REPRO_PALLAS_INTERPRET="1")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.check", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=570)


def test_cli_lint_section_exits_zero():
    proc = _run_cli("--only", "lint")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "== lint ==" in proc.stdout


def test_cli_seeded_violations_exits_nonzero_with_all_findings():
    """The CI inversion: on the seeded-violation fixtures the checker
    must exit non-zero AND report every seeded defect class first."""
    proc = _run_cli("--seeded-violations")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    for marker in ("kernel-oracle", "capability-consumed",
                   "recompile-hazard", "host-sync", "materialization"):
        assert marker in proc.stdout, f"missing {marker}:\n{proc.stdout}"


def test_cli_list_names_contracts_and_rules():
    proc = _run_cli("--list")
    assert proc.returncode == 0
    assert "stage1.stream.xla" in proc.stdout
    assert "recompile-hazard" in proc.stdout
