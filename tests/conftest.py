import copy

import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — unit tests/benches must see the real
# single CPU device; only launch/dryrun.py forces 512 host devices, and the
# multi-device tests spawn subprocesses with their own flags.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data.descriptors import make_synthetic_dataset
    return make_synthetic_dataset("deep", n_train=1500, n_base=4000,
                                  n_query=150, n_centers=64, seed=0)


@pytest.fixture(scope="session")
def tiny_unq(tiny_dataset):
    """A small UNQ model trained for a couple of epochs (shared by search /
    integration tests; quality asserted loosely, mechanics strictly)."""
    import jax.numpy as jnp
    from repro.core import unq, training

    cfg = unq.UNQConfig(dim=96, num_codebooks=8, codebook_size=64,
                        code_dim=32, hidden_dim=96)
    tcfg = training.TrainConfig(epochs=20, batch_size=256, lr=5e-3,
                                log_every=10)
    params, state, history = training.train_unq(tiny_dataset, cfg, tcfg)
    return cfg, params, state, history


# ---------------------------------------------------------------------------
# shared trained indexes: training a quantizer is the dominant cost of the
# index-level suites, and most tests only need SOME trained index — one
# session-scoped cache hands out cheap shallow clones (mutating a clone's
# backend / codes never touches the master or other tests)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def trained_index_factory(tiny_dataset):
    """``get(spec, **train_kw) -> trained+added Index`` with one training
    run per distinct (spec, train_kw) for the whole session.

    Returned objects are ``copy.copy`` clones of the cached master: all
    heavy state (model params, code buffers) is shared immutably, while
    attribute mutation (``index.backend = ...``) stays local to the
    clone. Tests that need to exercise training itself should keep
    building indexes from scratch instead.
    """
    from repro.index import index_factory

    cache = {}

    def get(spec: str, **train_kw):
        key = (spec, tuple(sorted(train_kw.items())))
        if key not in cache:
            index = index_factory(spec, dim=tiny_dataset.dim)
            index.train(tiny_dataset.train, **train_kw)
            index.add(tiny_dataset.base)
            cache[key] = index
        return copy.copy(cache[key])

    return get


# ---------------------------------------------------------------------------
# shared synthetic-case builders (deduplicated from test_topl / test_rerank /
# test_ivf): tie-heavy integer tables make score/distance collisions
# ubiquitous, so downstream parity checks exercise tie RESOLUTION, not just
# the score math
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def scan_case():
    """(rng, n, m, k, q, tie_heavy) -> (codes (N, M) u8, luts (Q, M, K))."""
    import jax.numpy as jnp

    def make(rng, n, m, k, q, tie_heavy):
        codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
        if tie_heavy:
            luts = jnp.asarray(rng.integers(-2, 3, (q, m, k)), jnp.float32)
        else:
            luts = jnp.asarray(rng.normal(size=(q, m, k)), jnp.float32)
        return codes, luts

    return make


@pytest.fixture(scope="session")
def rerank_case():
    """(rng, q, l, m, k, d, tie_heavy) -> (cand codes (Q, L, M) u8,
    queries (Q, D), decode table (M, K, D))."""
    import jax.numpy as jnp

    def make(rng, q, l, m, k, d, tie_heavy):
        cand = jnp.asarray(rng.integers(0, k, (q, l, m)), jnp.uint8)
        queries = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
        if tie_heavy:
            table = jnp.asarray(rng.integers(-2, 3, (m, k, d)), jnp.float32)
            queries = jnp.round(queries)
        else:
            table = jnp.asarray(rng.normal(size=(m, k, d)), jnp.float32)
        return cand, queries, table

    return make


@pytest.fixture(scope="session")
def ivf_flat_pair(trained_index_factory):
    """(ivf_spec_tail, train_kw) -> (IVFIndex, flat Index) over the SAME
    data with identically-trained quantizers (same seed/iters), the
    standing setup of the IVF==flat parity properties."""

    def make(quant: str, nlist: int, rerank: int = 50, **train_kw):
        flat = trained_index_factory(f"{quant},Rerank{rerank}", **train_kw)
        ivf = trained_index_factory(
            f"IVF{nlist},{quant},Rerank{rerank}", **train_kw)
        return ivf, flat

    return make
