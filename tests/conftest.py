import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — unit tests/benches must see the real
# single CPU device; only launch/dryrun.py forces 512 host devices, and the
# multi-device tests spawn subprocesses with their own flags.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data.descriptors import make_synthetic_dataset
    return make_synthetic_dataset("deep", n_train=1500, n_base=4000,
                                  n_query=150, n_centers=64, seed=0)


@pytest.fixture(scope="session")
def tiny_unq(tiny_dataset):
    """A small UNQ model trained for a couple of epochs (shared by search /
    integration tests; quality asserted loosely, mechanics strictly)."""
    import jax.numpy as jnp
    from repro.core import unq, training

    cfg = unq.UNQConfig(dim=96, num_codebooks=8, codebook_size=64,
                        code_dim=32, hidden_dim=96)
    tcfg = training.TrainConfig(epochs=20, batch_size=256, lr=5e-3,
                                log_every=10)
    params, state, history = training.train_unq(tiny_dataset, cfg, tcfg)
    return cfg, params, state, history
